"""Fig. 3 reproduction: % error (vs FP32 accumulation) of FP8 Gaussian
dot products, per summation algorithm, over dot-product length."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import formats, mgs, summation
from .common import Csv, timeit


def _fp8_pair(rng, k):
    x = rng.normal(0, 1, k).astype(np.float32)
    w = rng.normal(0, 1, k).astype(np.float32)
    f = formats.E4M3
    return (np.asarray(formats.round_to_format(x, f)),
            np.asarray(formats.round_to_format(w, f)))


def run(csv: Csv, lengths=(16, 64, 256, 1024, 4096), n_trials: int = 16):
    acc4 = summation.acc_format(4)   # the paper's 4-bit mantissa accumulator
    algos = {}

    def rel_err(est, ref):
        return abs(est - ref) / max(abs(ref), 1e-9)

    for k in lengths:
        errs = {a: [] for a in
                ("sequential", "pairwise", "kahan", "mgs_narrow_clip",
                 "mgs_dmac", "mgs_exact")}
        for t in range(n_trials):
            rng = np.random.default_rng(1000 * k + t)
            x, w = _fp8_pair(rng, k)
            p = np.asarray(mgs.round_product(
                jnp.asarray(x) * jnp.asarray(w), formats.E4M3)[0])
            ref = p.astype(np.float64).sum()  # FP32-accumulation oracle
            if abs(ref) < 1e-6:
                continue
            errs["sequential"].append(rel_err(
                float(summation.sequential_sum(jnp.asarray(p), acc4)), ref))
            errs["pairwise"].append(rel_err(
                float(summation.pairwise_sum(jnp.asarray(p), acc4)), ref))
            errs["kahan"].append(rel_err(
                float(summation.kahan_sum(jnp.asarray(p), acc4)), ref))
            errs["mgs_narrow_clip"].append(rel_err(float(
                mgs.mgs_dot_narrow_clipped(jnp.asarray(x),
                                           jnp.asarray(w))[0]), ref))
            errs["mgs_dmac"].append(rel_err(float(
                mgs.mgs_dot_exact(jnp.asarray(x), jnp.asarray(w),
                                  formats.E4M3, "dmac")), ref))
            true = float(np.sum(x.astype(np.float64) * w.astype(np.float64)))
            errs["mgs_exact"].append(
                abs(float(mgs.mgs_dot_exact(jnp.asarray(x), jnp.asarray(w),
                                            formats.E4M3, "exact")) - true)
                / max(abs(true), 1e-9))
        for a, es in errs.items():
            if es:
                csv.add(f"fig3/{a}/k={k}", 0.0,
                        f"pct_err={100 * float(np.mean(es)):.2f}")

    # one timing row (emulation cost on CPU, informational)
    rng = np.random.default_rng(0)
    x, w = _fp8_pair(rng, 1024)
    us = timeit(lambda: mgs.mgs_dot_exact(jnp.asarray(x), jnp.asarray(w)))
    csv.add("fig3/mgs_dot_exact_k1024_timing", us, "emulation")
