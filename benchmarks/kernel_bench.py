"""MGS matmul kernel micro-bench: interpret-mode wall time (CPU; the TPU
figure of merit is the structural analysis in §Roofline) plus the
analytic MXU-pass and HBM-traffic accounting of the limb kernels.

The fused-vs-unfused comparison tracks ISSUE-1's bandwidth claim: the
fused kernel streams packed FP8 codes (1 byte/elem) and decodes in VMEM,
so its operand HBM bytes are exactly 1/3 of the pre-decomposed kernel's
three int8 limb planes."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.kernels import ops, ref
from repro.kernels.mgs_matmul import worst_case_flush_period
from repro.core.markov import plan_flush_period
from .common import Csv, timeit


def hbm_bytes_exact(M: int, K: int, N: int, fused: bool) -> dict:
    """Analytic HBM traffic of one exact-mode (M,K)@(K,N) matmul.

    fused: packed FP8 codes, 1 B/elem per operand.
    unfused: 3 int8 limb planes per operand, 3 B/elem.
    Output is f32 either way.
    """
    per_elem = 1 if fused else 3
    operand = per_elem * (M * K + K * N)
    out = 4 * M * N
    return {"operand": operand, "out": out, "total": operand + out}


def decode_tiles(M: int, K: int, N: int, bm: int, bn: int, bk: int,
                 schedule: str) -> dict:
    """In-kernel operand-decode work (tiles decoded) of the fused kernel.

    Output-stationary decodes both operand tiles at every grid step:
    grid_m * grid_n * grid_k decodes each. The K-resident
    weight-stationary schedule decodes each weight tile once per output
    column (the i == 0 sweep): grid_n * grid_k — a grid_m-fold weight
    reduction. The symmetric activation-stationary schedule decodes each
    activation K-tile once per output row (the j == 0 sweep):
    grid_m * grid_k — a grid_n-fold activation reduction (wide-N layers
    such as the logits head).
    """
    gm, gn, gk = -(-M // bm), -(-N // bn), -(-K // bk)
    w_tiles = gn * gk if schedule == "weight" else gm * gn * gk
    x_tiles = gm * gk if schedule == "activation" else gm * gn * gk
    reduction = {"weight": gm, "activation": gn}.get(schedule, 1)
    return {"w_tiles": w_tiles, "x_tiles": x_tiles,
            "grid_m": gm, "grid_n": gn, "reduction": reduction}


def run(csv: Csv):
    rng = np.random.default_rng(0)
    f = formats.E4M3
    # (512, 256, 128) has grid_m = 4 so the weight-stationary schedule's
    # grid_m-fold decode reduction is visible in the report; the wide-N
    # (128, 256, 512) shape (grid_n = 4) does the same for the
    # activation-stationary schedule (the logits-head shape class)
    for (M, K, N) in [(64, 256, 64), (128, 512, 128), (512, 256, 128),
                      (128, 256, 512)]:
        x = jnp.asarray(np.asarray(formats.round_to_format(
            rng.normal(0, 1, (M, K)).astype(np.float32), f)))
        w = jnp.asarray(np.asarray(formats.round_to_format(
            rng.normal(0, 1, (K, N)).astype(np.float32), f)))
        # MXU-aligned 128 tiles: interpret mode then decodes each operand
        # tile once, matching the kernel's real per-tile work.
        us_u = timeit(lambda: ops.mgs_matmul(x, w, f, "exact",
                                             block_m=128, block_n=128,
                                             block_k=128), n=5)
        us_f = timeit(lambda: ops.mgs_matmul(x, w, f, "exact", fused=True,
                                             block_m=128, block_n=128,
                                             block_k=128), n=5)
        us_ws = timeit(lambda: ops.mgs_matmul(x, w, f, "exact", fused=True,
                                              schedule="weight",
                                              block_m=128, block_n=128,
                                              block_k=128), n=5)
        us_as = timeit(lambda: ops.mgs_matmul(x, w, f, "exact", fused=True,
                                              schedule="activation",
                                              block_m=128, block_n=128,
                                              block_k=128), n=5)
        us_r = timeit(lambda: ref.mgs_matmul_ref(x, w, f, "exact"), n=3)
        us_w = timeit(lambda: ref.wide_matmul_ref(x, w), n=3)
        bf = hbm_bytes_exact(M, K, N, fused=True)
        bu = hbm_bytes_exact(M, K, N, fused=False)
        dt_o = decode_tiles(M, K, N, 128, 128, 128, "output")
        dt_w = decode_tiles(M, K, N, 128, 128, 128, "weight")
        csv.add(f"kernel/exact_pallas_interp/{M}x{K}x{N}", us_u,
                f"ref_us={us_r:.0f};f32_us={us_w:.0f}")
        csv.add(
            f"kernel/exact_fused_interp/{M}x{K}x{N}", us_f,
            f"unfused_us={us_u:.0f};"
            f"hbm_operand_bytes={bf['operand']};"
            f"hbm_operand_bytes_unfused={bu['operand']};"
            f"operand_ratio={bf['operand'] / bu['operand']:.3f};"
            f"hbm_total_bytes={bf['total']};"
            f"hbm_total_bytes_unfused={bu['total']}")
        # ISSUE-2: K-resident weight-stationary schedule vs the PR 1
        # fused kernel — wall time plus analytic weight-decode work.
        csv.add(
            f"kernel/exact_fused_ws_interp/{M}x{K}x{N}", us_ws,
            f"output_stationary_us={us_f:.0f};"
            f"w_decode_tiles={dt_w['w_tiles']};"
            f"w_decode_tiles_output={dt_o['w_tiles']};"
            f"decode_reduction={dt_w['reduction']}x;"
            f"hbm_operand_bytes={bf['operand']}")
        # ISSUE-3: K-resident activation-stationary schedule — the
        # symmetric twin, cutting activation decode grid_n-fold.
        dt_a = decode_tiles(M, K, N, 128, 128, 128, "activation")
        csv.add(
            f"kernel/exact_fused_as_interp/{M}x{K}x{N}", us_as,
            f"output_stationary_us={us_f:.0f};"
            f"x_decode_tiles={dt_a['x_tiles']};"
            f"x_decode_tiles_output={dt_o['x_tiles']};"
            f"decode_reduction={dt_a['reduction']}x;"
            f"hbm_operand_bytes={bf['operand']}")
    # structural accounting: the limb kernel runs 9 int8 MXU passes per
    # bf16-equivalent matmul; v5e int8 throughput ~2x bf16 -> ~4.5x
    # bf16-matmul cost for *exact* FP8 accumulation (vs inexact fp32-acc).
    csv.add("kernel/exact_limb_mxu_passes", 0.0,
            "passes=9;int8_speedup=2.0;bf16_equiv_cost=4.5")
    csv.add("kernel/flush_period_bk128", 0.0,
            f"worst_case={worst_case_flush_period(128)};"
            f"markov_1e6={plan_flush_period(128, target_overflow=1e-6)}")
