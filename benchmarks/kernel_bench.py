"""MGS matmul kernel micro-bench: interpret-mode wall time (CPU; the TPU
figure of merit is the structural analysis in §Roofline) plus the
analytic MXU-pass accounting of the limb kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.kernels import ops, ref
from repro.kernels.mgs_matmul import worst_case_flush_period
from .common import Csv, timeit


def run(csv: Csv):
    rng = np.random.default_rng(0)
    f = formats.E4M3
    for (M, K, N) in [(64, 256, 64), (128, 512, 128)]:
        x = jnp.asarray(np.asarray(formats.round_to_format(
            rng.normal(0, 1, (M, K)).astype(np.float32), f)))
        w = jnp.asarray(np.asarray(formats.round_to_format(
            rng.normal(0, 1, (K, N)).astype(np.float32), f)))
        us_k = timeit(lambda: ops.mgs_matmul(x, w, f, "exact",
                                             block_m=64, block_n=64,
                                             block_k=128), n=3)
        us_r = timeit(lambda: ref.mgs_matmul_ref(x, w, f, "exact"), n=3)
        us_w = timeit(lambda: ref.wide_matmul_ref(x, w), n=3)
        csv.add(f"kernel/exact_pallas_interp/{M}x{K}x{N}", us_k,
                f"ref_us={us_r:.0f};f32_us={us_w:.0f}")
    # structural accounting: the limb kernel runs 9 int8 MXU passes per
    # bf16-equivalent matmul; v5e int8 throughput ~2x bf16 -> ~4.5x
    # bf16-matmul cost for *exact* FP8 accumulation (vs inexact fp32-acc).
    csv.add("kernel/exact_limb_mxu_passes", 0.0,
            "passes=9;int8_speedup=2.0;bf16_equiv_cost=4.5")
    csv.add("kernel/flush_period_bk128", 0.0,
            f"worst_case={worst_case_flush_period(128)}")
