"""Drift benchmark: stale vs streaming-refreshed flush plans under shift.

A synthetic serve stream of per-window activation limb draws changes
distribution mid-stream (the limb sigma widens, the way production
traffic drifts away from launch-day calibration). Three flush-planning
policies run over the same stream:

* ``static`` — the one-shot launch table (``quant.calibrate`` story):
  planned once from the pre-shift windows, never refreshed.
* ``adaptive`` — the ``quant.streaming`` loop: a gated
  :class:`~repro.quant.streaming.StreamingRecorder` EMA feeds a
  :class:`~repro.quant.streaming.StreamingCalibrator`, which hot-swaps
  a refreshed (version-bumped) table when the drift detector trips.
* ``fresh`` — the oracle: re-calibrated from every window's own
  empirical PMF (what a full offline re-calibration after the shift
  would plan).

Per window the error metric is the relative flush-plan error vs the
oracle, ``|period_policy - period_fresh| / period_fresh`` — the planned
period is the quantity MGS calibration exists to get right: it sets the
realized per-chunk overflow probability of the exact kernel's int32
class accumulators (reported alongside, via
:func:`~repro.core.markov.clt_overflow_prob`). Acceptance (steady state
after the shift): the adaptive plan recovers to within 10% of the fresh
baseline; the static plan does not (its sigma is ~2x stale, so its
period is ~4x off and its realized overflow probability blows through
the planning target by orders of magnitude).

Emits ``BENCH_drift.json`` (repo root) with the full per-window
trajectory and the acceptance verdict.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core.markov import clt_overflow_prob
from repro.quant.calibrate import ActivationRecorder, CalibrationTable
from repro.quant.streaming import StreamingCalibrator, StreamingRecorder

from .common import Csv

_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_drift.json")

_SITE = "bench.x"
_BLOCK_K = 64
_N_LIMBS = 3
_TARGET = 1e-6
_W_SIGMA = 20.0          # weight limb sigma (fixed: weights don't drift)
_WINDOWS = 60
_SHIFT_AT = 20
_SIGMA_PRE, _SIGMA_POST = 12.0, 30.0
_LIMBS_PER_WINDOW = 4096
_FINAL = 10              # steady-state windows scored for acceptance


def _window_limbs(rng, sigma):
    return np.clip(np.rint(rng.normal(0.0, sigma, _LIMBS_PER_WINDOW)),
                   -64, 63).astype(np.int64)


def _period(table: CalibrationTable) -> int:
    return table.flush_period(_SITE, _BLOCK_K, target_overflow=_TARGET,
                              sigma_limb_w=_W_SIGMA)


def _overflow(period: int, true_sigma: float) -> float:
    # realized per-chunk overflow probability of the planned period
    # under the window's *true* limb statistics (what the kernel's int32
    # class accumulators actually see)
    n_adds = period * _BLOCK_K * _N_LIMBS
    return float(clt_overflow_prob(n_adds, 32, true_sigma * _W_SIGMA))


def run(csv: Csv):
    rng = np.random.default_rng(0)
    sigmas = [_SIGMA_PRE] * _SHIFT_AT + \
        [_SIGMA_POST] * (_WINDOWS - _SHIFT_AT)

    # launch calibration: a batch recorder over the pre-shift regime
    launch = ActivationRecorder()
    for _ in range(4):
        launch.record(_SITE, _window_limbs(rng, _SIGMA_PRE))
    static_table = CalibrationTable.from_pairs(launch.table().to_pairs(),
                                               version=1)
    # decay 0.8: pre-shift mass is gone within ~10 sampled windows;
    # sigma_rtol 0.05: keep refreshing until the EMA sigma is within 5%
    # of the installed plan (period ~ sigma^-2, so that bounds the
    # steady-state plan error near the 10% acceptance line)
    cal = StreamingCalibrator(static_table,
                              recorder=StreamingRecorder(decay=0.8),
                              seed=0, sample_period=2, sigma_rtol=0.05,
                              min_calls=4)
    adaptive_table = [static_table]     # apply_fn target (hot-swap stand-in)

    records = []
    for i, sigma in enumerate(sigmas):
        limbs = _window_limbs(rng, sigma)
        if cal.should_sample(i):        # the deterministic shadow gate
            cal.recorder.record(_SITE, limbs)
        if cal.maybe_refresh(lambda t: adaptive_table.__setitem__(0, t)):
            csv.add(f"drift/refresh@w{i}", 0.0,
                    f"version={adaptive_table[0].version}")

        oracle = ActivationRecorder()
        oracle.record(_SITE, limbs)
        p_fresh = _period(oracle.table())
        p_static = _period(static_table)
        p_adapt = _period(adaptive_table[0])
        records.append({
            "window": i, "true_sigma": sigma,
            "period_fresh": p_fresh, "period_static": p_static,
            "period_adaptive": p_adapt,
            "err_static": abs(p_static - p_fresh) / p_fresh,
            "err_adaptive": abs(p_adapt - p_fresh) / p_fresh,
            "overflow_fresh": _overflow(p_fresh, sigma),
            "overflow_static": _overflow(p_static, sigma),
            "overflow_adaptive": _overflow(p_adapt, sigma),
            "table_version": adaptive_table[0].version,
        })

    tail = records[-_FINAL:]
    err_adapt = float(np.mean([r["err_adaptive"] for r in tail]))
    err_static = float(np.mean([r["err_static"] for r in tail]))
    ovf_static = float(np.max([r["overflow_static"] for r in tail]))
    recovered = err_adapt <= 0.10
    stale = err_static > 0.10
    summary = {
        "windows": _WINDOWS, "shift_at": _SHIFT_AT,
        "sigma_pre": _SIGMA_PRE, "sigma_post": _SIGMA_POST,
        "refreshes": cal.refreshes,
        "final_version": adaptive_table[0].version,
        "err_adaptive_final": err_adapt,
        "err_static_final": err_static,
        "overflow_static_final": ovf_static,
        "overflow_target": _TARGET,
        "adaptive_recovered": recovered,
        "static_stale": stale,
    }
    with open(_OUT, "w") as f:
        json.dump({"records": records, "summary": summary}, f, indent=1)

    csv.add("drift/adaptive_final_err", 0.0,
            f"err={err_adapt:.3f};recovered={recovered}")
    csv.add("drift/static_final_err", 0.0,
            f"err={err_static:.3f};stale={stale}")
    csv.add("drift/static_overflow", 0.0,
            f"p={ovf_static:.2e};target={_TARGET:.0e}")
    csv.add("drift/refreshes", 0.0,
            f"n={cal.refreshes};version={adaptive_table[0].version}")
    if not (recovered and stale):
        raise AssertionError(
            f"drift acceptance failed: adaptive err {err_adapt:.3f} "
            f"(want <= 0.10), static err {err_static:.3f} (want > 0.10)")
