"""Shared benchmark utilities: timing, CSV emission, a cached tiny trained
model used by the accuracy benchmarks (Table 1 / Fig. 9 proxies)."""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_CACHE = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")


def timeit(fn: Callable, *args, n: int = 3, warmup: int = 1) -> float:
    """Median wall microseconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


class Csv:
    """Collects ``name,us_per_call,derived`` rows (the harness contract)."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, us: float, derived):
        self.rows.append(f"{name},{us:.1f},{derived}")

    def dump(self):
        for r in self.rows:
            print(r)


def trained_tiny_lm(steps: int = 150, seed: int = 0):
    """Train (once, cached in-process) a tiny LM on the synthetic task;
    returns (cfg, params, eval_batches). Used as the paper's 'pre-trained
    model' stand-in for post-training quantization experiments."""
    global _TINY
    try:
        return _TINY
    except NameError:
        pass
    from repro.configs import reduced_config
    from repro.data import DataConfig, SyntheticLM
    from repro.models import init_params
    from repro.train import OptConfig, init_train_state, make_train_step

    cfg = reduced_config("mgs-paper-eval")
    params, _ = init_params(cfg, jax.random.PRNGKey(seed))
    state = init_train_state(params)
    step = jax.jit(make_train_step(
        cfg, OptConfig(lr=3e-3, warmup_steps=5, total_steps=steps)))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8, seed=seed))
    for i in range(steps):
        hb = data.make_batch(i)
        state, _ = step(state, {k: jnp.asarray(v) for k, v in hb.items()})
    evals = [data.make_batch(10_000 + i) for i in range(4)]
    _TINY = (cfg, state["params"], evals)
    return _TINY


def top1_accuracy(cfg, params, batches) -> float:
    """Next-token top-1 accuracy of the model on held-out batches."""
    from repro.models import forward
    hits = total = 0
    for hb in batches:
        batch = {k: jnp.asarray(v) for k, v in hb.items()}
        logits, _ = forward(params, cfg, batch)
        pred = jnp.argmax(logits, axis=-1)
        hits += int(jnp.sum(pred == batch["labels"]))
        total += int(np.prod(batch["labels"].shape))
    return hits / max(total, 1)
