"""Fig. 4 reproduction: (a) analytic overflow probability vs accumulator
bitwidth/length; (b) average accumulator bitwidth during emulated
quantized inference (5-bit weights x 7-bit activations, as in the paper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import int_dmac, markov
from .common import Csv


def run(csv: Csv):
    # (a) CLT overflow probabilities (paper's 5-bit w sigma=5, 7-bit x
    # sigma=21 setup)
    sigma_p = 5.0 * 21.0
    for a in (8, 10, 12, 14):
        for k in (5, 10, 15, 30):
            p = float(markov.clt_overflow_prob(k, a, sigma_p))
            csv.add(f"fig4a/acc{a}b/k={k}", 0.0, f"p_overflow={p:.4f}")

    # (b) average accumulator bitwidth across emulated layers: random
    # normal 5-bit weights x half-normal 7-bit activations (post-ReLU),
    # dMAC with narrow widths 8..14, wide=32.
    rng = np.random.default_rng(0)
    K = 576  # 1x1 conv over 64 channels x 3x3 receptive field scale
    n_dots = 64
    for nb in (8, 9, 10, 12):
        total_narrow = total_wide = 0
        for i in range(n_dots):
            w = np.clip(np.rint(rng.normal(0, 5, K)), -15, 15)
            x = np.clip(np.rint(np.abs(rng.normal(0, 21, K))), 0, 127)
            _, stats = int_dmac.int_dot_dmac(jnp.asarray(w), jnp.asarray(x),
                                             narrow_bits=nb)
            total_narrow += int(stats.narrow_adds)
            total_wide += int(stats.wide_flushes) + 1  # final drain
        avg = float(int_dmac.average_accumulator_bits(
            total_narrow, total_wide, nb, 32))
        csv.add(f"fig4b/narrow{nb}b", 0.0,
                f"avg_bits={avg:.2f};ovf_rate="
                f"{total_wide / max(total_narrow, 1):.4f}")
