"""Failover benchmark: recovery latency and throughput dip/restore vs R.

For each fleet size R the same request stream is served twice through
``repro.launch.replica.ReplicaServeDriver`` over a forced-4-host-device
set: once fault-free (baseline) and once with a persistent injected
fault that kills replica 0 mid-drain (retry budget exhausted ->
drain-and-requeue -> rebuild). Reported per R:

* ``recovery_s`` — detect-to-serving latency of the rebuild, from the
  driver's structured ``"rebuilt"`` event (supervisor drain + re-mesh +
  ``transfer_tree`` + health reset; never a re-quantization).
* ``rps_baseline`` / ``rps_fault`` and their ratio — the throughput dip
  the fault costs and how much the surviving replicas + the rebuilt
  replica restore.
* ``tokens_bitwise`` — the MGS determinism invariant: the faulted run's
  tokens are bitwise identical to the fault-free run's, every request.

Also emits ``BENCH_failover.json`` (repo root) with the full records.

On this CPU container the sub-meshes share physical cores, so the dip is
milder than on real disjoint-chip hardware; the row shape — bounded
recovery_s, restore ratio near 1, bitwise always true — is the point.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_DEVICES = 4
_N_REQUESTS = 12
_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_failover.json")

_SCRIPT = """
import dataclasses, json
import jax, numpy as np
from repro.configs import reduced_config
from repro.launch.replica import ReplicaServeDriver
from repro.launch.serve import Request
from repro.models import init_params
from repro.quant import QuantConfig
from repro.runtime.fault_tolerance import FaultInjector, FaultSpec

cfg = dataclasses.replace(reduced_config("deepseek-7b"), quant=
    QuantConfig(dtype="fp8_e4m3", accum="mgs_exact"))
params, dims = init_params(cfg, jax.random.PRNGKey(0))

def make_requests():
    rng = np.random.default_rng(0)
    return [Request(rid=i, prompt=rng.integers(1, cfg.vocab, 8).astype(
        np.int32), max_new_tokens=4) for i in range(%(n)d)]

def serve(R, injector=None):
    with ReplicaServeDriver(cfg, R, batch=2, max_len=16, params=params,
                            dims=dims, model_parallel=1, injector=injector,
                            max_retries=1, backoff_base_s=0.001) as driver:
        driver.warmup(prompt_len=8, max_new=4)
        reqs = make_requests()
        stats = driver.run(reqs)
        events = driver.events()
    return reqs, stats, events

rows = {}
for R in (2, 4):
    base_reqs, base, _ = serve(R)
    # replica 0 fails every execution of its first group incl. the retry,
    # exhausting max_retries=1 -> drain-and-requeue -> rebuild.
    inj = FaultInjector([FaultSpec(kind="raise", replica=0, group=0,
                                   count=2)])
    fault_reqs, fault, events = serve(R, injector=inj)
    recovery = [e["recovery_s"] for e in events if e["event"] == "rebuilt"]
    rows[R] = {
        "rps_baseline": base["requests_per_s"],
        "rps_fault": fault["requests_per_s"],
        "throughput_restore": fault["requests_per_s"]
                              / max(base["requests_per_s"], 1e-9),
        "recovery_s": recovery[0] if recovery else None,
        "retries": fault["retries"], "failovers": fault["failovers"],
        "requeued_requests": fault["requeued_requests"],
        "rebuilds": fault["rebuilds"],
        "tokens_bitwise": all(a.out_tokens == b.out_tokens
                              for a, b in zip(fault_reqs, base_reqs)),
        "complete": all(len(r.out_tokens) == 4 for r in fault_reqs),
    }
print(json.dumps(rows))
""" % {"n": _N_REQUESTS}


def run(csv):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_DEVICES}")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1800)
    if out.returncode != 0:
        csv.add("failover/error", 0.0,
                f"subprocess failed: {out.stderr[-200:]!r}")
        return
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    record = {"devices": _DEVICES, "n_requests": _N_REQUESTS, "rows": rows}
    with open(_OUT, "w") as f:
        json.dump(record, f, indent=1)
    for R, r in sorted(rows.items(), key=lambda kv: int(kv[0])):
        ok = r["tokens_bitwise"] and r["complete"] and r["rebuilds"] == 1
        csv.add(f"failover/recovery_r{R}",
                (r["recovery_s"] or 0.0) * 1e6,
                f"restore={r['throughput_restore']:.2f} "
                f"requeued={r['requeued_requests']} "
                f"bitwise={'yes' if ok else 'NO'}")
    csv.add("failover/record_file", 0.0, os.path.abspath(_OUT))
