"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Usage:

    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run fig3 table3  # subset
"""

from __future__ import annotations

import sys
import time

from .common import Csv


def main() -> None:
    from . import (decode_bench, drift, failover, fig3_dot_error,
                   fig4_overflow, fig5_markov, fig9_pareto, kernel_bench,
                   replica_throughput, roofline_table, serving_bench,
                   spec_bench, table1_accuracy, table3_energy)
    suites = {
        "fig3": fig3_dot_error.run,
        "fig4": fig4_overflow.run,
        "fig5": fig5_markov.run,
        "fig9": fig9_pareto.run,
        "table1": table1_accuracy.run,
        "table3": table3_energy.run,
        "kernel": kernel_bench.run,
        "roofline": roofline_table.run,
        "replica": replica_throughput.run,
        "decode": decode_bench.run,
        "failover": failover.run,
        "drift": drift.run,
        "serving": serving_bench.run,
        "spec": spec_bench.run,
    }
    want = sys.argv[1:] or list(suites)
    csv = Csv()
    print("name,us_per_call,derived")
    for name in want:
        t0 = time.time()
        suites[name](csv)
        csv.add(f"{name}/_suite_wall", (time.time() - t0) * 1e6, "ok")
    csv.dump()


if __name__ == "__main__":
    main()
