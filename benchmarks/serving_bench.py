"""Ragged-arrival serving benchmark: continuous batching vs fixed groups.

The same Poisson request stream (seeded — reruns see identical traffic)
with mixed prompt and output lengths is served two ways on the same
reduced model and hardware:

* **continuous** — :class:`repro.launch.serve.ContinuousBatchingEngine`
  with real arrival offsets (``serve(arrivals=...)``): requests are
  admitted into free slots between decode steps of the in-flight ones,
  so a long request never gates an unrelated short one.
* **fixed-group** — :class:`repro.launch.serve.ServeEngine` groups of
  ``batch`` in arrival order, simulated with measured service times: a
  group starts when its last member has arrived and the previous group
  finished, and every member waits for the group's slowest request (the
  head-of-line blocking continuous batching removes).

Per offered load the CSV reports p50/p99 request latency for both modes
and the continuous decode throughput; ``BENCH_serving.json`` (repo
root) carries the full records. The expected shape: comparable p50 at
low load, and a continuous p99 well under the fixed-group p99 as load
grows — tail latency is where group serving pays.

CPU-container caveat: absolute times are interpret-mode/CPU numbers;
the *ratio* between the modes is the point. Continuous mode's
per-request outputs are additionally traffic-invariant bit for bit
(tests/test_continuous.py pins that).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

_N_REQUESTS = 12
_SLOTS = 4
_MAX_LEN = 48
_BUCKETS = [8, 16]
_LOADS_RPS = (2.0, 8.0)   # offered load sweep (requests/second)


def _traffic(cfg, seed=0):
    """Seeded mixed-length request stream (plen 3..16, out 2..6)."""
    from repro.launch.serve import Request

    rng = np.random.default_rng(seed)
    reqs, plens, outs = [], [], []
    for i in range(_N_REQUESTS):
        plen = int(rng.integers(3, 17))
        out = int(rng.integers(2, 7))
        reqs.append(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=out))
        plens.append(plen)
        outs.append(out)
    return reqs, plens, outs


def _arrivals(rate_rps, seed=0):
    rng = np.random.default_rng(100 + int(rate_rps * 10) + seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, _N_REQUESTS)).tolist()


def _percentiles(lat):
    lat = np.asarray(lat)
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def _serve_continuous(eng, reqs, arrivals):
    stats = eng.serve(reqs, arrivals=arrivals)
    lat = [done - arr for arr, _, done in stats["timing"].values()]
    return lat, stats


def _serve_grouped(eng, reqs, arrivals):
    """Fixed groups of ``eng.batch`` in arrival order; measured service
    time per group, virtual queueing clock (group starts at
    max(previous group end, last member arrival))."""
    order = np.argsort(arrivals, kind="stable")
    lat, now = [], 0.0
    for g0 in range(0, len(order), eng.batch):
        idx = order[g0:g0 + eng.batch]
        group = [reqs[i] for i in idx]
        start = max(now, max(arrivals[i] for i in idx))
        t0 = time.monotonic()
        eng.run(group)
        end = start + (time.monotonic() - t0)
        lat.extend(end - arrivals[i] for i in idx)
        now = end
    return lat


def run(csv):
    import jax
    from repro.configs import reduced_config
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import ContinuousBatchingEngine, ServeEngine
    from repro.models import init_params
    from repro.quant.config import FP8_MGS_SERVE_PAGED

    q = FP8_MGS_SERVE_PAGED.replace(use_kernel=False, fused=False,
                                    block_m=32, block_n=32, block_k=32)
    cfg = dataclasses.replace(reduced_config("deepseek-7b"), quant=q)
    params, dims = init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))

    cont = ContinuousBatchingEngine(cfg, mesh, slots=_SLOTS,
                                    max_len=_MAX_LEN, params=params,
                                    dims=dims)
    cont.warmup(_BUCKETS, max_new=8)
    grp = ServeEngine(cfg, mesh, batch=_SLOTS, max_len=_MAX_LEN,
                      params=cont.params, dims=cont.dims)
    grp.warmup(_BUCKETS, max_new=8)

    record = {"n_requests": _N_REQUESTS, "slots": _SLOTS,
              "buckets": _BUCKETS, "loads_rps": list(_LOADS_RPS),
              "rows": {}}
    for rate in _LOADS_RPS:
        arrivals = _arrivals(rate)
        c_reqs, _, _ = _traffic(cfg)
        c_lat, c_stats = _serve_continuous(cont, c_reqs, arrivals)
        g_reqs, _, outs = _traffic(cfg)
        g_lat = _serve_grouped(grp, g_reqs, arrivals)
        # NOTE: tokens are not comparable across the modes — group mode
        # pads every member to the group's common bucket (neighbors
        # change the attended left-pad), which is exactly the coupling
        # continuous batching removes; its per-request bit-identity is
        # pinned in tests/test_continuous.py instead.
        complete = all(len(r.out_tokens) == o
                       for rs in (c_reqs, g_reqs)
                       for r, o in zip(rs, outs))
        c50, c99 = _percentiles(c_lat)
        g50, g99 = _percentiles(g_lat)
        row = {"p50_continuous_s": c50, "p99_continuous_s": c99,
               "p50_grouped_s": g50, "p99_grouped_s": g99,
               "p99_speedup": g99 / max(c99, 1e-9),
               "decode_tok_per_s": c_stats["decode_tok_per_s"],
               "decode_steps": c_stats["steps"],
               "complete": complete}
        record["rows"][f"{rate:g}"] = row
        csv.add(f"serving/p99_rps{rate:g}", c99 * 1e6,
                f"grouped_p99={g99:.3f}s speedup={row['p99_speedup']:.2f}x "
                f"complete={'yes' if complete else 'NO'}")
        csv.add(f"serving/p50_rps{rate:g}", c50 * 1e6,
                f"grouped_p50={g50:.3f}s "
                f"tok_per_s={row['decode_tok_per_s']:.1f}")
    with open(_OUT, "w") as f:
        json.dump(record, f, indent=1)
    csv.add("serving/record_file", 0.0, os.path.abspath(_OUT))
