"""Table 1 proxy: task accuracy of FP32 / INT8 / FP8(wide) / dMAC
inference on the same pre-trained model.

The paper evaluates ImageNet classification (MobileNetV2/ResNet-18/ViT);
no datasets ship with this container, so the proxy task is next-token
top-1 accuracy of a small LM trained on the structured synthetic stream
(benchmarks/common.py). The claim under test is the paper's: dMAC (MGS)
accuracy ~= FP8-with-wide-accumulation ~= FP32 baseline, while narrow
clipped accumulation degrades.
"""

from __future__ import annotations

import dataclasses

from repro.quant import QuantConfig
from .common import Csv, timeit, top1_accuracy, trained_tiny_lm

MODES = {
    "baseline_fp32": QuantConfig(),
    "int8": QuantConfig(dtype="int8", accum="wide"),
    "fp8_wide": QuantConfig(dtype="fp8_e4m3", accum="wide"),
    "dmac_mgs": QuantConfig(dtype="fp8_e4m3", accum="mgs_dmac"),
    "mgs_exact": QuantConfig(dtype="fp8_e4m3", accum="mgs_exact"),
    "fp8_swamp_narrow": QuantConfig(dtype="fp8_e4m3", accum="swamp",
                                    narrow_bits=5),
}


def run(csv: Csv):
    cfg, params, evals = trained_tiny_lm()
    base_acc = None
    for name, q in MODES.items():
        cfg_q = dataclasses.replace(cfg, quant=q)
        acc = top1_accuracy(cfg_q, params, evals)
        if name == "baseline_fp32":
            base_acc = acc
        csv.add(f"table1/{name}", 0.0,
                f"top1={acc:.4f};delta_vs_fp32={acc - base_acc:+.4f}")
