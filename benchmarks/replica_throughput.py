"""Replica-group serving throughput: aggregate requests/sec vs R.

Serves one fixed request stream through ``repro.launch.replica.
ReplicaServeDriver`` at R = 1, 2, 4 over a forced-4-host-device set (the
device count must be fixed at jax init, so the sweep runs in one
subprocess) and reports per-request wall time plus aggregate
requests/sec per R. Every engine keeps the deterministic
(``shard_batch=False``) layout, so the rows quantify exactly the
throughput the replica driver recovers *without* giving up bit-identical
logits; warmup compilation is excluded from the timed window.

On this CPU container the R sub-meshes share physical cores, so scaling
understates real accelerator behaviour (disjoint chips per replica);
the row shape — rps growing with R at fixed numerics — is the point.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_DEVICES = 4
_N_REQUESTS = 12

_SCRIPT = """
import dataclasses, json
import jax, numpy as np
from repro.configs import reduced_config
from repro.launch.replica import ReplicaServeDriver
from repro.launch.serve import Request
from repro.models import init_params
from repro.quant import QuantConfig

cfg = dataclasses.replace(reduced_config("deepseek-7b"), quant=
    QuantConfig(dtype="fp8_e4m3", accum="mgs_exact"))
params, dims = init_params(cfg, jax.random.PRNGKey(0))

def make_requests():
    rng = np.random.default_rng(0)
    return [Request(rid=i, prompt=rng.integers(1, cfg.vocab, 8).astype(
        np.int32), max_new_tokens=4) for i in range(%(n)d)]

rows = {}
for R in (1, 2, 4):
    with ReplicaServeDriver(cfg, R, batch=2, max_len=16,
                            params=params, dims=dims) as driver:
        driver.warmup(prompt_len=8, max_new=4)
        stats = driver.run(make_requests())
    rows[R] = {"wall_s": stats["wall_s"],
               "rps": stats["requests_per_s"],
               "decode_tok_per_s": stats["decode_tok_per_s"]}
print(json.dumps(rows))
""" % {"n": _N_REQUESTS}


def run(csv):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={_DEVICES}")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1800)
    if out.returncode != 0:
        csv.add("replica/error", 0.0,
                f"subprocess failed: {out.stderr[-200:]!r}")
        return
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    for R, r in sorted(rows.items(), key=lambda kv: int(kv[0])):
        csv.add(f"replica/requests_r{R}",
                r["wall_s"] * 1e6 / _N_REQUESTS,
                f"rps={r['rps']:.2f} decode_tok_per_s="
                f"{r['decode_tok_per_s']:.1f}")
