"""Speculative-decoding benchmark: draft/verify rounds vs sequential.

The same seeded request burst is served by
:class:`repro.launch.serve.ContinuousBatchingEngine` sequentially
(``spec_k=None``) and speculatively (truncated-layer self-draft + one
fused multi-query verify round per ``spec_k`` tokens,
``cfg.quant.draft_layers`` draft layers), at several slot counts — each
speculative row is compared against the sequential baseline *at its own
slot count*, on the same engine geometry, model, and traffic.

Because acceptance is exact (integer ``==`` against the verify argmax)
the spec engines must reproduce the sequential engine's tokens **bit
for bit**; the benchmark asserts that per request and reports it as
``bitwise`` per row — a speedup row with ``bitwise: false`` is a
correctness bug, not a trade-off.

The sweep shows the classic speculation economics: the win is largest
at slots=1 (the latency-bound regime — per-round fixed costs amortize
across the k verify positions while the sequential lane pays them per
token) and shrinks as slots grow and per-row compute fills the step.
Per row the CSV/JSON report decode throughput, rounds, acceptance
rate, tokens per round, and speedup; ``BENCH_spec.json`` (repo root)
carries the full records.

CPU-container caveat: absolute tok/s are emulation-tier numbers; the
*ratio* is the point. On real accelerators the analogous fixed costs
are kernel launches and the per-step HBM weight/cache streams
(docs/serving.md#speculative-decoding--bitwise-exact-draftverify-rounds).

``REPRO_SPEC_BENCH_FAST=1`` shrinks the sweep to a CI smoke
(sequential + k=2 at slots=1 on a short burst) — same engines, same
bitwise assertion.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

_OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_spec.json")

_MAX_LEN = 128
_BUCKETS = [16]
_N_REQUESTS = 8
_MAX_NEW = 40
# (slots, spec_k, draft_layers); spec_k None = the sequential baseline
_SWEEP = ((1, None, 0), (1, 2, 1), (1, 4, 1), (1, 8, 1), (1, 8, 2),
          (2, None, 0), (2, 8, 1),
          (4, None, 0), (4, 8, 1))
_SWEEP_FAST = ((1, None, 0), (1, 2, 1))


def _fast() -> bool:
    return bool(os.environ.get("REPRO_SPEC_BENCH_FAST"))


def _traffic(cfg, n_requests, max_new, seed=3):
    """Seeded burst: mixed prompt lengths, all admissible at t=0."""
    from repro.launch.serve import Request

    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        int(rng.integers(4, 15)))
                    .astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n_requests)]


def _serve(cfg, mesh, params, dims, slots, spec_k, n_requests, max_new):
    """Best-of-N serves of the same burst on one warmed engine.

    Decode here is host-dispatch-bound, so a busy container can halve a
    single serve's throughput; the max over repeats estimates the
    uncontended rate the same way for every row (sequential and
    speculative alike). The engine's determinism contract makes the
    repeats byte-for-byte replays — asserted below.
    """
    from repro.launch.serve import ContinuousBatchingEngine

    eng = ContinuousBatchingEngine(cfg, mesh, slots=slots,
                                   max_len=_MAX_LEN, params=params,
                                   dims=dims, spec_k=spec_k)
    eng.warmup(_BUCKETS, max_new=4)
    repeats = 1 if _fast() else 3
    best_stats, tokens = None, None
    for _ in range(repeats):
        reqs = _traffic(cfg, n_requests, max_new)
        stats = eng.serve(reqs)
        toks = {r.rid: list(r.out_tokens) for r in reqs}
        assert tokens is None or toks == tokens, \
            "serve repeats diverged — determinism bug"
        tokens = toks
        if (best_stats is None or stats["decode_tok_per_s"]
                > best_stats["decode_tok_per_s"]):
            best_stats = stats
    return best_stats, tokens


def run(csv):
    import jax
    from repro.configs import reduced_config
    from repro.launch.mesh import make_mesh
    from repro.models import init_params
    from repro.quant.config import FP8_MGS_SERVE_PAGED

    q = FP8_MGS_SERVE_PAGED.replace(use_kernel=False, fused=False,
                                    block_m=32, block_n=32, block_k=32)
    base_cfg = dataclasses.replace(reduced_config("deepseek-7b"), quant=q)
    params, dims = init_params(base_cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))

    sweep = _SWEEP_FAST if _fast() else _SWEEP
    n_requests = 4 if _fast() else _N_REQUESTS
    max_new = 12 if _fast() else _MAX_NEW

    record = {"n_requests": n_requests, "max_new": max_new,
              "buckets": _BUCKETS, "fast": _fast(), "rows": {}}
    seq = {}          # slots -> (tok/s, tokens) of the sequential row
    best = (0.0, None)
    for slots, spec_k, dl in sweep:
        if spec_k is None:
            name, cfg = f"slots{slots}_sequential", base_cfg
        else:
            name = f"slots{slots}_k{spec_k}_dl{dl}"
            cfg = dataclasses.replace(
                base_cfg, quant=q.replace(draft_layers=dl))
        stats, tokens = _serve(cfg, mesh, params, dims, slots, spec_k,
                               n_requests, max_new)
        row = {"slots": slots,
               "decode_tok_per_s": stats["decode_tok_per_s"],
               "decode_tokens": stats["decode_tokens"],
               "steps": stats["steps"]}
        if spec_k is None:
            seq[slots] = (row["decode_tok_per_s"], tokens)
            derived = f"steps={stats['steps']}"
        else:
            sp = stats["spec"]
            seq_tps, seq_tokens = seq[slots]
            bitwise = tokens == seq_tokens
            assert bitwise, (
                f"{name}: speculative tokens diverged from the "
                f"sequential baseline — exact-acceptance bug")
            row.update(
                acceptance_rate=sp["acceptance_rate"],
                tokens_per_round=stats["decode_tokens"]
                / max(stats["steps"], 1),
                speedup_vs_sequential=row["decode_tok_per_s"] / seq_tps,
                bitwise=bitwise)
            if row["speedup_vs_sequential"] > best[0]:
                best = (row["speedup_vs_sequential"], name)
            derived = (f"speedup={row['speedup_vs_sequential']:.2f}x "
                       f"acc={sp['acceptance_rate']:.2f} "
                       f"tpr={row['tokens_per_round']:.2f} "
                       f"bitwise={'yes' if bitwise else 'NO'}")
        record["rows"][name] = row
        csv.add(f"spec/{name}",
                1e6 / max(row["decode_tok_per_s"], 1e-9), derived)
    record["best_speedup"] = best[0]
    record["best_config"] = best[1]
    csv.add("spec/best", 0.0,
            f"{best[1]}={best[0]:.2f}x over sequential at equal slots")
    if not _fast():
        # the CI smoke must not clobber the tracked full-sweep record
        with open(_OUT, "w") as f:
            json.dump(record, f, indent=1)
        csv.add("spec/record_file", 0.0, os.path.abspath(_OUT))
