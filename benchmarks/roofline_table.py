"""Emit the §Roofline table from the dry-run JSON records."""

from __future__ import annotations

import glob
import json
import os

from .common import Csv


def run(csv: Csv, root: str = "experiments/dryrun"):
    files = sorted(glob.glob(os.path.join(root, "*.json")))
    if not files:
        csv.add("roofline/missing", 0.0,
                "run `python -m repro.launch.dryrun --all` first")
        return
    for f in files:
        r = json.load(open(f))
        tag = os.path.basename(f)[:-5]
        if r.get("skipped"):
            csv.add(f"roofline/{tag}", 0.0, "skipped")
            continue
        if "error" in r:
            csv.add(f"roofline/{tag}", 0.0, "ERROR")
            continue
        csv.add(
            f"roofline/{tag}", r["compile_s"] * 1e6,
            f"t_comp={r['t_compute']:.3f}s;t_mem={r['t_memory']:.3f}s;"
            f"t_coll={r['t_collective']:.3f}s;bn={r['bottleneck']};"
            f"peak_frac={r['peak_fraction']:.3f};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"live_gb={r['memory_per_device']['live_bytes'] / 1e9:.2f}")


def markdown_table(root: str = "experiments/dryrun") -> str:
    rows = ["| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) |"
            " bottleneck | peak frac | 6ND/HLO | live GB | fits |",
            "|---|---|---|---|---|---|---|---|---|---|---|"[:-4]]
    for f in sorted(glob.glob(os.path.join(root, "*.json"))):
        r = json.load(open(f))
        if r.get("skipped") or "error" in r:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.3f} | {r['t_memory']:.3f} "
            f"| {r['t_collective']:.3f} | {r['bottleneck']} "
            f"| {r['peak_fraction']:.3f} | {r['useful_flops_ratio']:.2f} "
            f"| {r['memory_per_device']['live_bytes'] / 1e9:.2f} "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(rows)
